"""Partition plane (PR 5): sharded/partitioned vs single-device resident.

Four sections:

* ``partitioned_fused_*`` -- the partition plane as shipped (adaptive
  dispatch: single-shard stacked-plan kernels below the SPMD threshold,
  ``shard_map`` across the device mesh above it) against the monolithic
  single-device resident path, per engine / batch size / partition count
  (1, 2, 4, 8 -- 1 is the degenerate case and must be a wash).  The
  partitioned dispatch additionally caps its page-padding ladder at the
  stacked plan, which is where it pulls ahead at page-heavy batches.

* ``partitioned_spmd_*`` -- the forced ``shard_map`` tail
  (``SHARD_MIN_PAGES=0``), the multi-device scaling diagnostic.  On this
  CI host the "devices" are forced CPU shards of two cores, so these
  rows measure dispatch overhead, not real scaling; they exist to track
  the SPMD path's cost over time (re-measure on real accelerators).

* ``partitioned_pruned_*`` -- statistics pushdown: label-filtered
  retrieval over a community-local graph where partitions' min/max id
  hulls miss the predicate's qualifying range, so the partition plane
  skips their decode and I/O wholesale.  Since PR 10 the monolithic
  path page-prunes to the *same* final page set (partition-pruned
  pages are a subset of page-pruned ones), so these rows pin a wash --
  partition hulls are now a cheap coarse pre-filter, and the pruning
  win itself is measured A/B against a no-prune baseline in
  ``bench_pruning``.  Ids are asserted identical; the derived column
  records the pruned-partition count and the I/O delta (now 0).

* interpret-mode rows (``REPRO_INTERPRET=1``): the pallas rows rerun
  with the suffix ``_interp`` -- on CPU the pallas engine always runs
  the kernels in interpret mode, and these rows pin that cost explicitly
  in the tracked trajectory (ROADMAP interpret-mode follow-up).

Every timed comparison is preceded by a bit-identity + IOMeter assertion
against the single-device path (and for pruned rows, an ids-only
assertion plus a bytes-strictly-less check).  ``REPRO_BENCH_SMOKE=1``
shrinks the graph so CI runs the suite in seconds.  Run with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to put the SPMD
rows on an 8-shard mesh (without it they degenerate to one device).
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import (BY_SRC, ENC_GRAPHAR, IOMeter, L, LabelFilter,
                        build_adjacency, live_partitions, partition_column,
                        retrieve_neighbors_batch)
from repro.core.schema import VertexTypeSchema
from repro.core.vertex import VertexTable
from repro.kernels.pac_decode import ops as pdo

from .util import emit

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
INTERP = bool(os.environ.get("REPRO_INTERPRET"))
N = 2_000 if SMOKE else 20_000
DEG = 8 if SMOKE else 16
PAGE = 512 if SMOKE else 2048
BATCH_SIZES = (64,) if SMOKE else (64, 512)
PART_COUNTS = (2,) if SMOKE else (1, 2, 4, 8)
REPS = 8 if SMOKE else 120


def _paired(fa, fb, reps=REPS):
    """Interleaved A/B timing (see bench_resident): min us/call for each
    plus the median of per-pair ratios (drift-robust on a shared box)."""
    fa(), fb(), fa(), fb()
    ta, tb = [], []
    for i in range(reps):
        pair = (fa, ta), (fb, tb)
        for fn, acc in (pair if i % 2 == 0 else pair[::-1]):
            t0 = time.perf_counter()
            fn()
            acc.append(time.perf_counter() - t0)
    ratios = sorted(b / a for a, b in zip(ta, tb))
    return (min(ta) * 1e6, min(tb) * 1e6, ratios[len(ratios) // 2])


def _fixture(local=False):
    if local:
        # perfectly community-local graph: each partition's value hull
        # tracks its source range, the regime GraphAr's chunked layouts
        # (and LDBC-style community graphs) put you in -- statistics
        # pruning has teeth here.  Clipped (not wrapped) neighbors: a
        # single wrap-around edge would stretch a boundary partition's
        # min/max hull across the whole id space.
        off = np.concatenate([np.arange(-(DEG // 2), 0),
                              np.arange(1, DEG - DEG // 2 + 1)])
        src = np.repeat(np.arange(N), len(off))
        dst = np.clip(np.arange(N)[:, None] + off[None, :], 0, N - 1).ravel()
    else:
        from repro.data.synthetic import powerlaw_graph
        src, dst = powerlaw_graph(N, DEG, locality=0.85, seed=11)
    return src, dst


def _adj(src, dst):
    return build_adjacency(src, dst, N, N, BY_SRC, ENC_GRAPHAR,
                           page_size=PAGE)


def _check_identity(mono, part, vs, engine, filt=None, exact_meter=True):
    m_a, m_b = IOMeter(), IOMeter()
    f = (lambda: LabelFilter(filt.vt, filt.cond)) if filt else lambda: None
    want = retrieve_neighbors_batch(mono, vs, PAGE, m_a, engine=engine,
                                    fused=True, resident=True, filter=f())
    got = retrieve_neighbors_batch(part, vs, PAGE, m_b, engine=engine,
                                   fused=True, resident=True, filter=f())
    assert got == want, "partitioned ids must match single-device"
    if exact_meter:
        assert (m_a.nbytes, m_a.nrequests) == (m_b.nbytes, m_b.nrequests), \
            "partitioned IOMeter must match single-device"
    else:
        assert m_b.nbytes <= m_a.nbytes, "pruning may only remove I/O"
    return m_a.nbytes, m_b.nbytes


def _engines():
    eng = ["jax", "pallas"]
    if INTERP:
        eng.append("pallas_interp")  # same engine, explicit interp row tag
    return eng


def _resolve(engine):
    return ("pallas", "_interp") if engine == "pallas_interp" \
        else (engine, "")


def run() -> None:
    src, dst = _fixture()
    mono = _adj(src, dst)

    # ---- adaptive partitioned vs single-device resident -------------------
    for engine in _engines():
        eng, tag = _resolve(engine)
        for bs in BATCH_SIZES:
            vs = np.random.default_rng(bs).integers(0, N, bs)
            fm = lambda: retrieve_neighbors_batch(
                mono, vs, PAGE, engine=eng, fused=True, resident=True)
            for n_parts in PART_COUNTS:
                part = _adj(src, dst)
                partition_column(part.table["<dst>"].encoded, n_parts)
                _check_identity(mono, part, vs, eng)
                fp = lambda: retrieve_neighbors_batch(
                    part, vs, PAGE, engine=eng, fused=True, resident=True)
                t_mono, t_part, ratio = _paired(fm, fp)
                emit(f"partitioned_fused_{eng}{tag}_p{n_parts}_bs{bs}",
                     t_part,
                     f"mono_us={t_mono:.2f};"
                     f"partitioned_over_mono={1 / ratio:.2f};"
                     f"io_identical=1")
                # drift-robust speedup as its own JSON row (x100):
                # the median of per-pair ratios from the interleaved run
                emit(f"partitioned_fused_{eng}{tag}_p{n_parts}_bs{bs}"
                     ":speedup_pct", 100 / ratio, "")
            emit(f"mono_fused_{eng}{tag}_bs{bs}", t_mono, "")

    # ---- forced-SPMD diagnostic rows --------------------------------------
    import jax
    n_dev = len(jax.devices())
    saved = pdo.SHARD_MIN_PAGES
    pdo.SHARD_MIN_PAGES = 0
    try:
        for engine in _engines():
            eng, tag = _resolve(engine)
            for bs in BATCH_SIZES[-1:]:
                vs = np.random.default_rng(bs).integers(0, N, bs)
                fm = lambda: retrieve_neighbors_batch(
                    mono, vs, PAGE, engine=eng, fused=True, resident=True)
                for n_parts in PART_COUNTS:
                    if n_parts == 1:
                        continue
                    part = _adj(src, dst)
                    partition_column(part.table["<dst>"].encoded, n_parts)
                    _check_identity(mono, part, vs, eng)
                    parts = live_partitions(part.table["<dst>"].encoded)
                    g = parts.mesh_size(n_dev)
                    fp = lambda: retrieve_neighbors_batch(
                        part, vs, PAGE, engine=eng, fused=True,
                        resident=True)
                    t_mono, t_part, ratio = _paired(fm, fp)
                    emit(f"partitioned_spmd_{eng}{tag}_p{n_parts}_bs{bs}",
                         t_part,
                         f"mono_us={t_mono:.2f};"
                         f"spmd_over_mono={1 / ratio:.2f};"
                         f"mesh_devices={g};io_identical=1")
                    emit(f"partitioned_spmd_{eng}{tag}_p{n_parts}_bs{bs}"
                         ":speedup_pct", 100 / ratio, "")
    finally:
        pdo.SHARD_MIN_PAGES = saved

    # ---- statistics pushdown (label filter x partition hulls) -------------
    src, dst = _fixture(local=True)
    mono = _adj(src, dst)
    labels = {"HOT": np.arange(N) < N // 4}
    lvt = VertexTable.build(
        VertexTypeSchema("v", [], labels=["HOT"], page_size=PAGE),
        {}, labels, num_vertices=N)
    for engine in _engines():
        eng, tag = _resolve(engine)
        for bs in BATCH_SIZES:
            vs = np.random.default_rng(bs).integers(0, N, bs)
            filt_m = LabelFilter(lvt, L("HOT"))
            fm = lambda: retrieve_neighbors_batch(
                mono, vs, PAGE, engine=eng, fused=True, resident=True,
                filter=filt_m)
            for n_parts in PART_COUNTS:
                if n_parts == 1:
                    continue
                part = _adj(src, dst)
                partition_column(part.table["<dst>"].encoded, n_parts)
                nb_mono, nb_part = _check_identity(
                    mono, part, vs, eng, filt=filt_m, exact_meter=False)
                filt_p = LabelFilter(lvt, L("HOT"))
                fp = lambda: retrieve_neighbors_batch(
                    part, vs, PAGE, engine=eng, fused=True, resident=True,
                    filter=filt_p)
                t_mono, t_part, ratio = _paired(fm, fp)
                parts = live_partitions(part.table["<dst>"].encoded)
                emit(f"partitioned_pruned_{eng}{tag}_p{n_parts}_bs{bs}",
                     t_part,
                     f"mono_us={t_mono:.2f};"
                     f"pruned_over_mono={1 / ratio:.2f};"
                     f"stats_pruned={parts.stats_pruned};"
                     f"io_saved_pct={100 * (1 - nb_part / max(nb_mono, 1)):.0f};"
                     f"ids_identical=1")
                emit(f"partitioned_pruned_{eng}{tag}_p{n_parts}_bs{bs}"
                     ":speedup_pct", 100 / ratio, "")
