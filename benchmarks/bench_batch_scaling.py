"""Batch-size scaling of the batched retrieval plane.

Four sections:

* packed-page cache: cold build vs hot reuse of the column-wide batch
  arrays (``pack_column``);
* loop vs batch: one ``retrieve_neighbors_batch`` call against the
  per-vertex ``retrieve_neighbors`` Python loop, across all engines, with
  the I/O plane's view (bytes/requests saved by page dedup);
* fused vs host (PR 2): the fused decode->bitmap kernel path against the
  decode + ``PAC.from_ids`` host path on the jax/pallas engines, with the
  IOMeter cross-checked against the numpy engine (identical by
  construction -- the row asserts it);
* cold vs warm decoded-page LRU (PR 2): repeated serving-tick retrievals
  (``neighbor_ids_batch``) with the cache cleared each call vs pre-warmed.

``REPRO_BENCH_SMOKE=1`` shrinks the graph and batch sizes so CI can run
the whole file in seconds as a kernel-regression tripwire.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import (BY_SRC, ENC_GRAPHAR, IOMeter, attach_page_cache,
                        build_adjacency, neighbor_ids_batch, pack_column,
                        retrieve_neighbors, retrieve_neighbors_batch)

from .util import emit, timeit

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
BATCH_SIZES = (1, 8) if SMOKE else (1, 8, 64, 512)
KERNEL_BATCH_SIZES = (8,) if SMOKE else (8, 64, 512)
ENGINES = ("numpy", "jax", "pallas")
N = 2_000 if SMOKE else 20_000
DEG = 8
PAGE = 512 if SMOKE else 2048
CACHE_PAGES = 256


def run() -> None:
    from repro.data.synthetic import powerlaw_graph
    src, dst = powerlaw_graph(N, DEG, locality=0.85, seed=11)
    adj = build_adjacency(src, dst, N, N, BY_SRC, ENC_GRAPHAR,
                          page_size=PAGE)

    # packed-page cache: cold build vs hot reuse (per-query cost removed)
    col = adj.table["<dst>"].encoded
    col.packed_cache = None
    t_cold = timeit(lambda: (setattr(col, "packed_cache", None),
                             pack_column(col)), repeats=3)
    t_hot = timeit(lambda: pack_column(col), repeats=5)
    emit("batch_pack_pages_cold", t_cold, "")
    emit("batch_pack_pages_hot", t_hot,
         f"cold_over_hot={t_cold / max(t_hot, 1e-9):.0f}x")

    for engine in ENGINES:
        for bs in BATCH_SIZES:
            # same batch across engines so rows are comparable
            vs = np.random.default_rng(bs).integers(0, N, bs)
            reps = 1 if engine == "pallas" else 3

            t_loop = timeit(
                lambda: [retrieve_neighbors(adj, int(v), PAGE,
                                            engine=engine) for v in vs],
                repeats=reps)
            t_batch = timeit(
                lambda: retrieve_neighbors_batch(adj, vs, PAGE,
                                                 engine=engine),
                repeats=reps)

            m_loop, m_batch = IOMeter(), IOMeter()
            for v in vs:
                retrieve_neighbors(adj, int(v), PAGE, m_loop, engine)
            retrieve_neighbors_batch(adj, vs, PAGE, m_batch, engine)

            emit(f"batch_scaling_{engine}_bs{bs}", t_batch,
                 f"loop_us={t_loop:.2f};speedup={t_loop / t_batch:.2f};"
                 f"io_bytes_batch={m_batch.nbytes};"
                 f"io_bytes_loop={m_loop.nbytes};"
                 f"io_reqs_batch={m_batch.nrequests};"
                 f"io_reqs_loop={m_loop.nrequests}")

    # ---- fused decode->bitmap vs decode + PAC.from_ids host path ----------
    for engine in ("jax", "pallas"):
        for bs in KERNEL_BATCH_SIZES:
            vs = np.random.default_rng(bs).integers(0, N, bs)
            t_fused = timeit(
                lambda: retrieve_neighbors_batch(adj, vs, PAGE,
                                                 engine=engine, fused=True),
                repeats=5)
            t_host = timeit(
                lambda: retrieve_neighbors_batch(adj, vs, PAGE,
                                                 engine=engine, fused=False),
                repeats=5)
            m_fused, m_np = IOMeter(), IOMeter()
            retrieve_neighbors_batch(adj, vs, PAGE, m_fused, engine,
                                     fused=True)
            retrieve_neighbors_batch(adj, vs, PAGE, m_np, "numpy")
            assert (m_fused.nbytes, m_fused.nrequests) \
                == (m_np.nbytes, m_np.nrequests), \
                "fused path must charge exactly what the numpy engine does"
            emit(f"batch_fused_{engine}_bs{bs}", t_fused,
                 f"host_us={t_host:.2f};fused_over_host="
                 f"{t_host / t_fused:.2f};io_bytes={m_fused.nbytes};"
                 f"io_bytes_numpy={m_np.nbytes};io_identical=1")
            emit(f"batch_host_{engine}_bs{bs}", t_host, "")

    # ---- decoded-page LRU: cold vs warm serving ticks ---------------------
    for engine in ENGINES:
        for bs in KERNEL_BATCH_SIZES:
            vs = np.random.default_rng(bs).integers(0, N, bs)
            cache = attach_page_cache(col, CACHE_PAGES)

            def cold_tick():
                cache.clear()
                neighbor_ids_batch(adj, vs, engine=engine)

            t_cold = timeit(cold_tick, repeats=3)
            neighbor_ids_batch(adj, vs, engine=engine)   # warm the cache
            t_warm = timeit(
                lambda: neighbor_ids_batch(adj, vs, engine=engine),
                repeats=5)
            m_cold, m_warm = IOMeter(), IOMeter()
            cache.clear()
            neighbor_ids_batch(adj, vs, m_cold, engine=engine)
            neighbor_ids_batch(adj, vs, m_warm, engine=engine)
            col.page_cache = None
            emit(f"batch_lru_warm_{engine}_bs{bs}", t_warm,
                 f"cold_us={t_cold:.2f};cold_over_warm="
                 f"{t_cold / t_warm:.2f};io_bytes_cold={m_cold.nbytes};"
                 f"io_bytes_warm={m_warm.nbytes}")
            emit(f"batch_lru_cold_{engine}_bs{bs}", t_cold, "")
