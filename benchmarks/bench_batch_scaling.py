"""Batch-size scaling of the batched retrieval plane.

For batch = 1/8/64/512 vertices, compares one ``retrieve_neighbors_batch``
call (vectorized offsets gather + page-deduplicated decode + merged PAC)
against the per-vertex ``retrieve_neighbors`` Python loop, across all
three decode engines.  Also reports the I/O plane's view (bytes/requests
saved by page dedup) and the packed-page cache effect on the kernel
engines' hot path.
"""
from __future__ import annotations

import numpy as np

from repro.core import (BY_SRC, ENC_GRAPHAR, IOMeter, build_adjacency,
                        pack_column, retrieve_neighbors,
                        retrieve_neighbors_batch)

from .util import emit, timeit

BATCH_SIZES = (1, 8, 64, 512)
ENGINES = ("numpy", "jax", "pallas")
N = 20_000
DEG = 8
PAGE = 2048


def run() -> None:
    from repro.data.synthetic import powerlaw_graph
    src, dst = powerlaw_graph(N, DEG, locality=0.85, seed=11)
    adj = build_adjacency(src, dst, N, N, BY_SRC, ENC_GRAPHAR,
                          page_size=PAGE)

    # packed-page cache: cold build vs hot reuse (per-query cost removed)
    col = adj.table["<dst>"].encoded
    col.packed_cache = None
    t_cold = timeit(lambda: (setattr(col, "packed_cache", None),
                             pack_column(col)), repeats=3)
    t_hot = timeit(lambda: pack_column(col), repeats=5)
    emit("batch_pack_pages_cold", t_cold, "")
    emit("batch_pack_pages_hot", t_hot,
         f"cold_over_hot={t_cold / max(t_hot, 1e-9):.0f}x")

    for engine in ENGINES:
        for bs in BATCH_SIZES:
            # same batch across engines so rows are comparable
            vs = np.random.default_rng(bs).integers(0, N, bs)
            reps = 1 if engine == "pallas" else 3

            t_loop = timeit(
                lambda: [retrieve_neighbors(adj, int(v), PAGE,
                                            engine=engine) for v in vs],
                repeats=reps)
            t_batch = timeit(
                lambda: retrieve_neighbors_batch(adj, vs, PAGE,
                                                 engine=engine),
                repeats=reps)

            m_loop, m_batch = IOMeter(), IOMeter()
            for v in vs:
                retrieve_neighbors(adj, int(v), PAGE, m_loop, engine)
            retrieve_neighbors_batch(adj, vs, PAGE, m_batch, engine)

            emit(f"batch_scaling_{engine}_bs{bs}", t_batch,
                 f"loop_us={t_loop:.2f};speedup={t_loop / t_batch:.2f};"
                 f"io_bytes_batch={m_batch.nbytes};"
                 f"io_bytes_loop={m_loop.nbytes};"
                 f"io_reqs_batch={m_batch.nrequests};"
                 f"io_reqs_loop={m_loop.nrequests}")
