"""Pipelined serving plane (PR 8): saturation curve, tick latency, speedup.

Open-loop load: ``lam`` requests arrive per tick (RAG requests, each
naming a seed vertex in the lake); the engine runs a fixed number of
ticks and we record the per-tick latency distribution (p50/p99), the
sustained tick throughput, and the completed-request rate.  Three serve
modes on identical workloads:

* ``baseline`` -- the pre-restructuring tick (per-request prefill
  dispatch+sync, per-slot sample reads, synchronous retrieval):
  ``ServeEngine(batched=False, pipeline=False)``;
* ``seq``      -- the restructured tick (grouped batched prefill, one
  batched sample read) with synchronous retrieval;
* ``pipe``     -- the restructured tick plus the speculative retrieval
  prefetch issued in the decode's shadow (``REPRO_PIPELINE`` default).

The acceptance row ``serving_saturation_speedup`` compares ``pipe``
against ``baseline`` at the highest offered load (saturation): the
serving plane this PR ships vs. the one it replaced, same model, same
lake, same arrivals.  On a multi-core host the prefetch overlap adds to
this; on a single-core CI runner the win is the restructuring itself.

Before any timing, ``pipe`` is asserted **bit-identical** to ``seq``
(request ids, output tokens, IOMeter bytes/requests, page-cache
hits/misses) -- speculation must only move wall time.  The steady-state
portion of the pipelined saturation run is also asserted retrace-free
(kernel trace counters flat) and the count is emitted.

Workload construction: fixed-length prompts and seed vertices whose
assembled context exceeds the context budget, so every admitted prompt
has one length -- admission compiles once and steady state stays
shape-stable.  ``REPRO_BENCH_SMOKE=1`` shrinks everything for CI.
"""
from __future__ import annotations

import os
import time
from typing import List

import numpy as np

from repro.core import (BY_SRC, EdgeTypeSchema, GraphArBuilder, IOMeter,
                        PropertySchema, VertexTypeSchema)
from repro.data.synthetic import document_graph
from repro.serve.engine import Request, ServeEngine
from repro.serve.retrieval import GraphRetriever

from .util import emit

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
N_DOCS = 1_000 if SMOKE else 8_000
SLOTS = 8 if SMOKE else 16
MNT = 8                 # steady-state generation length per request
P0 = 4                  # raw prompt tokens before context attachment
BUDGET = 9              # context budget -> every prompt is P0+BUDGET long
MAX_LEN = 1 + P0 + BUDGET + MNT
NB, TPN = 16, 16
CACHE_PAGES = 64
TICKS = 40 if SMOKE else 100
WARM_TICKS = 10
LAMS = (2, 8) if SMOKE else (1, 2, 4, 8)
RETR_ENGINES = ("jax",) if SMOKE else ("jax", "pallas")


def _lake():
    lake = document_graph(num_docs=N_DOCS, vocab=512, mean_len=48, seed=5)
    b = GraphArBuilder("docs")
    b.add_vertices(
        VertexTypeSchema("doc", [PropertySchema("tokens", "tokens")],
                         labels=list(lake.labels), page_size=128),
        {"tokens": lake.tokens}, lake.labels)
    b.add_edges(EdgeTypeSchema("doc", "links", "doc", page_size=128),
                lake.links_src, lake.links_dst)
    g = b.build()
    return g.adjacency("doc-links-doc", BY_SRC), \
        g.vertex("doc").table["tokens"]


def _fixed_len_seeds(adj, tok) -> np.ndarray:
    """Seed vertices whose assembled context is >= BUDGET tokens, so the
    engine's budget clamp makes every prompt exactly P0+BUDGET long."""
    probe = GraphRetriever(adj, tok, max_neighbors=NB,
                           tokens_per_neighbor=TPN, engine="numpy",
                           page_cache_pages=None)
    cand = np.flatnonzero(adj.degrees() >= 2)[:3000]
    ctx = probe(cand)
    seeds = np.asarray([v for v, c in zip(cand, ctx) if len(c) >= BUDGET])
    assert seeds.size >= 64, "lake too sparse for fixed-length workload"
    return seeds


def _requests(cfg, seeds, n) -> List[Request]:
    """The offered request stream: the first wave carries staggered
    generation lengths so steady state retires ~SLOTS/MNT slots per tick
    instead of whole cohorts at once."""
    rng = np.random.default_rng(1)
    vs = seeds[rng.integers(0, len(seeds), n)]
    return [Request(i, rng.integers(4, cfg.vocab_size, size=P0)
                    .astype(np.int32),
                    max_new_tokens=2 + (i % MNT) if i < SLOTS else MNT,
                    context_vertex=int(v))
            for i, v in enumerate(vs)]


def _engine(model, params, adj, tok, retr_engine, mode):
    retr = GraphRetriever(adj, tok, max_neighbors=NB,
                          tokens_per_neighbor=TPN, meter=IOMeter(),
                          engine=retr_engine,
                          page_cache_pages=CACHE_PAGES)
    cache = retr.page_cache
    if cache is not None:
        cache.clear()
        cache.reset_stats()
    return ServeEngine(model, params, max_slots=SLOTS, max_len=MAX_LEN,
                       eos_id=-1, context_fn=retr,
                       pipeline=(mode == "pipe"),
                       batched=(mode != "baseline"))


def _run_load(eng, it, lam, ticks):
    """Open-loop: submit ``lam`` arrivals then tick, ``ticks`` times.
    ``it`` is a shared request iterator so split runs (warmup slice +
    measured slice) see one continuous arrival stream.  Returns per-tick
    latencies (ms) and completed count."""
    lat = []
    done0 = len(eng.finished)
    for _ in range(ticks):
        for _ in range(lam):
            r = next(it, None)
            if r is not None:
                eng.submit(r)
        t0 = time.perf_counter()
        eng.step()
        lat.append((time.perf_counter() - t0) * 1e3)
    return np.asarray(lat), len(eng.finished) - done0


def _drain(eng, max_ticks=10_000):
    while eng.queue or any(s is not None for s in eng.slots):
        eng.step()
        max_ticks -= 1
        if max_ticks <= 0:
            raise RuntimeError("serving bench failed to drain")


def _assert_identical(model, params, cfg, adj, tok, seeds, retr_engine):
    """pipe == seq before anything is timed: ids, tokens, IOMeter,
    page-cache counters."""
    fins, stats = [], []
    for mode in ("seq", "pipe"):
        eng = _engine(model, params, adj, tok, retr_engine, mode)
        _run_load(eng, iter(_requests(cfg, seeds, 3 * SLOTS)), 2,
                  3 * SLOTS // 2)
        _drain(eng)
        retr = eng.context_fn
        fins.append(eng.finished)
        stats.append((retr.meter.nbytes, retr.meter.nrequests, retr.calls,
                      retr.page_cache.hits, retr.page_cache.misses))
    a, b = fins
    assert [r.request_id for r in a] == [r.request_id for r in b]
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.prompt, rb.prompt)
        assert ra.output == rb.output
    assert stats[0] == stats[1], f"accounting diverged: {stats}"


def run() -> None:
    from repro.configs import get_config
    from repro.kernels._pad import trace_count
    from repro.models import build_model
    cfg = get_config("smollm-360m").reduced().with_(n_units=2)
    model = build_model(cfg)
    params = model.init(0)
    adj, tok = _lake()
    seeds = _fixed_len_seeds(adj, tok)

    sats = {}
    for retr_engine in RETR_ENGINES:
        _assert_identical(model, params, cfg, adj, tok, seeds, retr_engine)
        sat = sats.setdefault(retr_engine, {})
        for mode in ("baseline", "seq", "pipe"):
            for lam in LAMS:
                # Warm pass replays the exact arrival pattern so every
                # prefill-group shape the timed run admits is already
                # compiled (workload is deterministic: greedy, eos=-1).
                warm = _engine(model, params, adj, tok, retr_engine, mode)
                _run_load(warm, iter(_requests(cfg, seeds, lam * TICKS)),
                          lam, TICKS)
                eng = _engine(model, params, adj, tok, retr_engine, mode)
                it = iter(_requests(cfg, seeds, lam * TICKS))
                # steady-state retrace check rides the timed run
                lat_w, done_w = _run_load(eng, it, lam, WARM_TICKS)
                t_before = trace_count()
                steady, done_s = _run_load(eng, it, lam,
                                           TICKS - WARM_TICKS)
                retraces = trace_count() - t_before
                done = done_w + done_s
                ticks_s = len(steady) / (steady.sum() / 1e3)
                p50 = float(np.percentile(steady, 50))
                p99 = float(np.percentile(steady, 99))
                total_s = (lat_w.sum() + steady.sum()) / 1e3
                req_s = done / max(total_s, 1e-9)
                emit(f"serving_{retr_engine}_{mode}_lam{lam}",
                     float(np.median(steady)) * 1e3,
                     f"p50={p50:.2f}ms p99={p99:.2f}ms "
                     f"ticks_s={ticks_s:.1f} req_s={req_s:.1f}")
                if lam == LAMS[-1]:
                    sat[mode] = ticks_s
                    if mode == "pipe":
                        ps = eng.stats()["pipeline"]
                        emit(f"serving_{retr_engine}_pipe_stats",
                             ps["pipeline_overlap_ms"] * 1e3 /
                             max(eng.steps, 1),
                             f"prefetch_hits={ps['prefetch_hits']} "
                             f"mis_speculations={ps['mis_speculations']} "
                             f"retraces={retraces}")
                        assert retraces == 0, \
                            f"steady state retraced {retraces}x"

    sat = sats[RETR_ENGINES[0]]
    emit("serving_saturation_speedup", 1e6 / sat["pipe"],
         f"pipelined_vs_baseline={sat['pipe'] / sat['baseline']:.2f}x "
         f"overlap_vs_seq={sat['pipe'] / sat['seq']:.2f}x "
         f"at_lam={LAMS[-1]}")
