"""Pipelined serving plane (PR 8): saturation curve, tick latency, speedup.

Open-loop load: ``lam`` requests arrive per tick (RAG requests, each
naming a seed vertex in the lake); the engine runs a fixed number of
ticks and we record the per-tick latency distribution (p50/p99), the
sustained tick throughput, and the completed-request rate.  Three serve
modes on identical workloads:

* ``baseline`` -- the pre-restructuring tick (per-request prefill
  dispatch+sync, per-slot sample reads, synchronous retrieval):
  ``ServeEngine(batched=False, pipeline=False)``;
* ``seq``      -- the restructured tick (grouped batched prefill, one
  batched sample read) with synchronous retrieval;
* ``pipe``     -- the restructured tick plus the speculative retrieval
  prefetch issued in the decode's shadow (``REPRO_PIPELINE`` default).

The acceptance row ``serving_saturation_speedup`` compares ``pipe``
against ``baseline`` at the highest offered load (saturation): the
serving plane this PR ships vs. the one it replaced, same model, same
lake, same arrivals.  On a multi-core host the prefetch overlap adds to
this; on a single-core CI runner the win is the restructuring itself.

Before any timing, ``pipe`` is asserted **bit-identical** to ``seq``
(request ids, output tokens, IOMeter bytes/requests, page-cache
hits/misses) -- speculation must only move wall time.  The steady-state
portion of the pipelined saturation run is also asserted retrace-free
(kernel trace counters flat) and the count is emitted.

Workload construction: fixed-length prompts and seed vertices whose
assembled context exceeds the context budget, so every admitted prompt
has one length -- admission compiles once and steady state stays
shape-stable.  ``REPRO_BENCH_SMOKE=1`` shrinks everything for CI.
"""
from __future__ import annotations

import os
import time
from typing import List

import numpy as np

from repro.core import (BY_SRC, EdgeTypeSchema, GraphArBuilder, IOMeter,
                        PropertySchema, VertexTypeSchema)
from repro.data.synthetic import document_graph
from repro.serve.engine import Request, ServeEngine
from repro.serve.overload import OverloadConfig
from repro.serve.retrieval import GraphRetriever
from repro.serve.tenancy import RequestStatus, TenantConfig

from .util import emit

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
N_DOCS = 1_000 if SMOKE else 8_000
SLOTS = 8 if SMOKE else 16
MNT = 8                 # steady-state generation length per request
P0 = 4                  # raw prompt tokens before context attachment
BUDGET = 9              # context budget -> every prompt is P0+BUDGET long
MAX_LEN = 1 + P0 + BUDGET + MNT
NB, TPN = 16, 16
CACHE_PAGES = 64
TICKS = 40 if SMOKE else 100
WARM_TICKS = 10
LAMS = (2, 8) if SMOKE else (1, 2, 4, 8)
RETR_ENGINES = ("jax",) if SMOKE else ("jax", "pallas")


def _lake():
    lake = document_graph(num_docs=N_DOCS, vocab=512, mean_len=48, seed=5)
    b = GraphArBuilder("docs")
    b.add_vertices(
        VertexTypeSchema("doc", [PropertySchema("tokens", "tokens")],
                         labels=list(lake.labels), page_size=128),
        {"tokens": lake.tokens}, lake.labels)
    b.add_edges(EdgeTypeSchema("doc", "links", "doc", page_size=128),
                lake.links_src, lake.links_dst)
    g = b.build()
    return g.adjacency("doc-links-doc", BY_SRC), \
        g.vertex("doc").table["tokens"]


def _fixed_len_seeds(adj, tok) -> np.ndarray:
    """Seed vertices whose assembled context is >= BUDGET tokens, so the
    engine's budget clamp makes every prompt exactly P0+BUDGET long."""
    probe = GraphRetriever(adj, tok, max_neighbors=NB,
                           tokens_per_neighbor=TPN, engine="numpy",
                           page_cache_pages=None)
    cand = np.flatnonzero(adj.degrees() >= 2)[:3000]
    ctx = probe(cand)
    seeds = np.asarray([v for v, c in zip(cand, ctx) if len(c) >= BUDGET])
    assert seeds.size >= 64, "lake too sparse for fixed-length workload"
    return seeds


def _requests(cfg, seeds, n) -> List[Request]:
    """The offered request stream: the first wave carries staggered
    generation lengths so steady state retires ~SLOTS/MNT slots per tick
    instead of whole cohorts at once."""
    rng = np.random.default_rng(1)
    vs = seeds[rng.integers(0, len(seeds), n)]
    return [Request(i, rng.integers(4, cfg.vocab_size, size=P0)
                    .astype(np.int32),
                    max_new_tokens=2 + (i % MNT) if i < SLOTS else MNT,
                    context_vertex=int(v))
            for i, v in enumerate(vs)]


def _engine(model, params, adj, tok, retr_engine, mode):
    retr = GraphRetriever(adj, tok, max_neighbors=NB,
                          tokens_per_neighbor=TPN, meter=IOMeter(),
                          engine=retr_engine,
                          page_cache_pages=CACHE_PAGES)
    cache = retr.page_cache
    if cache is not None:
        cache.clear()
        cache.reset_stats()
    return ServeEngine(model, params, max_slots=SLOTS, max_len=MAX_LEN,
                       eos_id=-1, context_fn=retr,
                       pipeline=(mode == "pipe"),
                       batched=(mode != "baseline"))


def _run_load(eng, it, lam, ticks):
    """Open-loop: submit ``lam`` arrivals then tick, ``ticks`` times.
    ``it`` is a shared request iterator so split runs (warmup slice +
    measured slice) see one continuous arrival stream.  Returns per-tick
    latencies (ms) and completed count."""
    lat = []
    done0 = len(eng.finished)
    for _ in range(ticks):
        for _ in range(lam):
            r = next(it, None)
            if r is not None:
                eng.submit(r)
        t0 = time.perf_counter()
        eng.step()
        lat.append((time.perf_counter() - t0) * 1e3)
    return np.asarray(lat), len(eng.finished) - done0


def _drain(eng, max_ticks=10_000):
    while eng.queue or any(s is not None for s in eng.slots):
        eng.step()
        max_ticks -= 1
        if max_ticks <= 0:
            raise RuntimeError("serving bench failed to drain")


def _assert_identical(model, params, cfg, adj, tok, seeds, retr_engine):
    """pipe == seq before anything is timed: ids, tokens, IOMeter,
    page-cache counters."""
    fins, stats = [], []
    for mode in ("seq", "pipe"):
        eng = _engine(model, params, adj, tok, retr_engine, mode)
        _run_load(eng, iter(_requests(cfg, seeds, 3 * SLOTS)), 2,
                  3 * SLOTS // 2)
        _drain(eng)
        retr = eng.context_fn
        fins.append(eng.finished)
        stats.append((retr.meter.nbytes, retr.meter.nrequests, retr.calls,
                      retr.page_cache.hits, retr.page_cache.misses))
    a, b = fins
    assert [r.request_id for r in a] == [r.request_id for r in b]
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.prompt, rb.prompt)
        assert ra.output == rb.output
    assert stats[0] == stats[1], f"accounting diverged: {stats}"


def run() -> None:
    from repro.configs import get_config
    from repro.kernels._pad import trace_count
    from repro.models import build_model
    cfg = get_config("smollm-360m").reduced().with_(n_units=2)
    model = build_model(cfg)
    params = model.init(0)
    adj, tok = _lake()
    seeds = _fixed_len_seeds(adj, tok)

    sats = {}
    for retr_engine in RETR_ENGINES:
        _assert_identical(model, params, cfg, adj, tok, seeds, retr_engine)
        sat = sats.setdefault(retr_engine, {})
        for mode in ("baseline", "seq", "pipe"):
            for lam in LAMS:
                # Warm pass replays the exact arrival pattern so every
                # prefill-group shape the timed run admits is already
                # compiled (workload is deterministic: greedy, eos=-1).
                warm = _engine(model, params, adj, tok, retr_engine, mode)
                _run_load(warm, iter(_requests(cfg, seeds, lam * TICKS)),
                          lam, TICKS)
                eng = _engine(model, params, adj, tok, retr_engine, mode)
                it = iter(_requests(cfg, seeds, lam * TICKS))
                # steady-state retrace check rides the timed run
                lat_w, done_w = _run_load(eng, it, lam, WARM_TICKS)
                t_before = trace_count()
                steady, done_s = _run_load(eng, it, lam,
                                           TICKS - WARM_TICKS)
                retraces = trace_count() - t_before
                done = done_w + done_s
                ticks_s = len(steady) / (steady.sum() / 1e3)
                p50 = float(np.percentile(steady, 50))
                p99 = float(np.percentile(steady, 99))
                total_s = (lat_w.sum() + steady.sum()) / 1e3
                req_s = done / max(total_s, 1e-9)
                emit(f"serving_{retr_engine}_{mode}_lam{lam}",
                     float(np.median(steady)) * 1e3,
                     f"p50={p50:.2f}ms p99={p99:.2f}ms "
                     f"ticks_s={ticks_s:.1f} req_s={req_s:.1f}")
                if lam == LAMS[-1]:
                    sat[mode] = ticks_s
                    if mode == "pipe":
                        ps = eng.stats()["pipeline"]
                        emit(f"serving_{retr_engine}_pipe_stats",
                             ps["pipeline_overlap_ms"] * 1e3 /
                             max(eng.steps, 1),
                             f"prefetch_hits={ps['prefetch_hits']} "
                             f"mis_speculations={ps['mis_speculations']} "
                             f"retraces={retraces}")
                        assert retraces == 0, \
                            f"steady state retraced {retraces}x"

    sat = sats[RETR_ENGINES[0]]
    emit("serving_saturation_speedup", 1e6 / sat["pipe"],
         f"pipelined_vs_baseline={sat['pipe'] / sat['baseline']:.2f}x "
         f"overlap_vs_seq={sat['pipe'] / sat['seq']:.2f}x "
         f"at_lam={LAMS[-1]}")


# ------------------- admission & overload (PR 9) --------------------------
#
# Open-loop offered load at 1x/2x/4x the service capacity (CAP requests
# per tick sustained by SLOTS slots retiring every ~MNT ticks), two
# tenant classes (latency-sensitive ``prod`` weight 8, ``batch`` weight 1
# with a deadline), crossed with {no admission, admission+shedding}.
# The acceptance contrast: under 4x overload the admission engine keeps
# queue depth bounded by the configured per-tenant queue caps while the
# no-admission baseline's backlog grows without bound; prod keeps its
# sojourn p99 flat because DWRR weight + rate caps shield it from batch
# floods.  A final row drives the overload ladder (impossibly low
# latency target) and asserts serving continues, degraded, retrace-free.

OV_TICKS = 30 if SMOKE else 80
CAP = max(1, SLOTS // MNT)          # sustainable arrivals per tick
MULTS = (1, 2, 4)
BATCH_DEADLINE = 3 * MNT


def _ov_tenants():
    return [TenantConfig("prod", weight=8, rate=0.75 * CAP,
                         burst=float(SLOTS), max_queue=2 * SLOTS),
            TenantConfig("batch", weight=1, rate=0.5 * CAP,
                         burst=float(SLOTS), max_queue=SLOTS,
                         deadline_ticks=BATCH_DEADLINE)]


def _ov_requests(cfg, seeds, n):
    rng = np.random.default_rng(2)
    vs = seeds[rng.integers(0, len(seeds), n)]
    out = []
    for i, v in enumerate(vs):
        r = Request(i, rng.integers(4, cfg.vocab_size, size=P0)
                    .astype(np.int32),
                    max_new_tokens=2 + (i % MNT) if i < SLOTS else MNT,
                    context_vertex=int(v))
        r.tenant = "prod" if i % 2 == 0 else "batch"
        out.append(r)
    return out


def _ov_engine(model, params, adj, tok, admit, overload=None):
    retr = GraphRetriever(adj, tok, max_neighbors=NB,
                          tokens_per_neighbor=TPN, meter=IOMeter(),
                          engine=RETR_ENGINES[0],
                          page_cache_pages=CACHE_PAGES)
    if retr.page_cache is not None:
        retr.page_cache.clear()
        retr.page_cache.reset_stats()
    return ServeEngine(model, params, max_slots=SLOTS, max_len=MAX_LEN,
                       eos_id=-1, context_fn=retr, pipeline=True,
                       tenants=_ov_tenants() if admit else None,
                       overload=overload)


def _ov_run(eng, cfg, seeds, mult, ticks, drain=True):
    """Offer ``mult * CAP`` arrivals per tick for ``ticks`` ticks, then
    (optionally) drain.  Returns per-tick queue depth, per-tick latency
    (ms), and the submit outcomes."""
    reqs = iter(_ov_requests(cfg, seeds, mult * CAP * ticks))
    depth, lat, outcomes = [], [], []
    for _ in range(ticks):
        for _ in range(mult * CAP):
            r = next(reqs, None)
            if r is not None:
                outcomes.append(eng.submit(r))
        t0 = time.perf_counter()
        eng.step()
        lat.append((time.perf_counter() - t0) * 1e3)
        depth.append(eng.stats()["queued"])
    if drain:
        eng.run_until_drained(max_ticks=50_000)
    return np.asarray(depth), np.asarray(lat), outcomes


def _sojourn(eng, tenant):
    """Per-class sojourn (submit -> retire, in ticks) over OK finishes."""
    ts = [r.finished_tick - r.submitted_tick for r in eng.finished
          if r.tenant == tenant and r.status in (None, RequestStatus.OK)
          and r.finished_tick is not None and r.submitted_tick is not None]
    if not ts:
        return float("nan"), float("nan")
    a = np.asarray(ts, float)
    return float(np.percentile(a, 50)), float(np.percentile(a, 99))


def run_overload() -> None:
    from repro.configs import get_config
    from repro.kernels._pad import trace_count
    from repro.models import build_model
    cfg = get_config("smollm-360m").reduced().with_(n_units=2)
    model = build_model(cfg)
    params = model.init(0)
    adj, tok = _lake()
    seeds = _fixed_len_seeds(adj, tok)
    hard_bound = sum(t.max_queue for t in _ov_tenants())

    peak_depth = {}
    for mult in MULTS:
        for admit in (False, True):
            mode = "admit" if admit else "noadmit"
            # warm pass: compile every admission shape this load offers
            warm = _ov_engine(model, params, adj, tok, admit)
            _ov_run(warm, cfg, seeds, mult, OV_TICKS, drain=False)
            eng = _ov_engine(model, params, adj, tok, admit)
            depth, lat, outcomes = _ov_run(eng, cfg, seeds, mult, OV_TICKS)
            s = eng.stats()
            rejected = s.get("rejected", 0)
            expired = (s.get("deadline_exceeded", 0) or 0)
            pp50, pp99 = _sojourn(eng, "prod")
            bp50, bp99 = _sojourn(eng, "batch")
            submitted = len(outcomes)
            finished = len(eng.finished)
            # exactly-one-bucket accounting: every offered request either
            # finished (OK or deadline) or was shed with a typed outcome
            assert finished + rejected == submitted, \
                f"{mode} x{mult}: {finished}+{rejected} != {submitted}"
            if admit:
                assert depth.max() <= hard_bound, \
                    f"admission queue depth {depth.max()} > {hard_bound}"
            peak_depth[(mode, mult)] = int(depth.max())
            emit(f"overload_{mode}_x{mult}",
                 float(np.percentile(lat, 99)) * 1e3,
                 f"prod_sojourn_p50={pp50:.0f} prod_p99={pp99:.0f} "
                 f"batch_p50={bp50:.0f} batch_p99={bp99:.0f} "
                 f"depth_max={depth.max()} depth_end={depth[-1]} "
                 f"rejected={rejected} expired={expired} "
                 f"finished={finished}/{submitted}")

    # the acceptance contrast at 4x: bounded vs unbounded backlog
    assert peak_depth[("admit", 4)] <= hard_bound
    assert peak_depth[("noadmit", 4)] > peak_depth[("admit", 4)], \
        "no-admission baseline failed to out-queue the admission engine"
    emit("overload_bounded_vs_unbounded", float(peak_depth[("noadmit", 4)]),
         f"noadmit_depth={peak_depth[('noadmit', 4)]} "
         f"admit_depth={peak_depth[('admit', 4)]} bound={hard_bound} at_x4")

    # degradation ladder under sustained overload: an unreachable latency
    # target forces every rung; serving must keep ticking, stay accurate
    # in its accounting, and hold steady state retrace-free
    ov = OverloadConfig(target_p99_ms=1e-6, window=4, patience=1)
    warm = _ov_engine(model, params, adj, tok, True, overload=ov)
    _ov_run(warm, cfg, seeds, 4, OV_TICKS, drain=False)
    eng = _ov_engine(model, params, adj, tok, True, overload=ov)
    reqs = iter(_ov_requests(cfg, seeds, 4 * CAP * OV_TICKS))
    for _ in range(OV_TICKS // 3):      # ladder engages in the first third
        for _ in range(4 * CAP):
            r = next(reqs, None)
            if r is not None:
                eng.submit(r)
        eng.step()
    t_before = trace_count()
    for _ in range(OV_TICKS - OV_TICKS // 3):
        for _ in range(4 * CAP):
            r = next(reqs, None)
            if r is not None:
                eng.submit(r)
        eng.step()
    retraces = trace_count() - t_before
    eng.run_until_drained(max_ticks=50_000)
    ostats = eng.stats()["overload"]
    assert ostats["level"] == 3, f"ladder never fully engaged: {ostats}"
    assert eng.finished, "degraded engine stopped serving"
    assert retraces == 0, f"degraded steady state retraced {retraces}x"
    emit("overload_ladder", float(ostats["degrade_steps"]),
         f"level={ostats['level']} degrade={ostats['degrade_steps']} "
         f"restore={ostats['restore_steps']} retraces={retraces} "
         f"finished={len(eng.finished)}")
