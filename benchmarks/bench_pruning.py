"""Page-granular statistics pushdown (PR 10): zone maps vs full decode.

Three sections, each A/B against the *same* retrieval with page pruning
disabled (``prune_page_list`` patched to a pass-through -- the pre-PR
behaviour; partition hulls are not involved, the column is monolithic,
so the page-level sieve is the only variable):

* ``page_pruned_label_*`` -- selective label-filtered retrieval over a
  community-local graph: the predicate's qualifying hull covers the
  first eighth of the id space, so ~7/8 of the touched pages are
  zone-map-pruned before staging -- never gathered, never decoded,
  never charged.  Ids are asserted bit-identical to the unpruned
  oracle and I/O bytes strictly less before any timing.

* ``page_pruned_numeric_*`` -- the same regime through a
  :class:`~repro.core.numeric.NumericFilter` (``AGE < N/8``): numeric
  ``Cond`` leaves derive the same hull, and the filter's own property
  reads are zone-map-skipped on top.

* ``page_unpruned_*`` -- an all-true predicate whose hull covers the
  whole id space: nothing prunes, meters are asserted *exactly* equal
  to the patched baseline, and the emitted ratio tracks that the sieve
  is free when it has nothing to cut (the prune check is a vectorised
  host-side hull intersect over the deduplicated page list).

A final steady-state check warms the pruned path, then asserts zero
retraces over measured ticks with varying batch sizes (the pruned
staged vectors keep the unpruned request's pow2 size class, so pruning
never mints a new jit shape).  ``REPRO_BENCH_SMOKE=1`` shrinks the
graph so CI runs the suite in seconds; interpret-mode rows follow the
bench_partition convention (``*_interp`` suffix).
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import (BY_SRC, ENC_GRAPHAR, IOMeter, L, LabelFilter,
                        NumericFilter, NumProp, build_adjacency,
                        retrieve_neighbors_batch)
from repro.core.schema import PropertySchema, VertexTypeSchema
from repro.core.vertex import VertexTable
from repro.kernels import _pad
from repro.kernels.pac_decode import ops as pdo

from .util import emit

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
INTERP = bool(os.environ.get("REPRO_INTERPRET"))
N = 2_000 if SMOKE else 20_000
DEG = 8 if SMOKE else 16
PAGE = 512 if SMOKE else 2048
BATCH_SIZES = (64,) if SMOKE else (64, 512)
REPS = 8 if SMOKE else 120


def _paired(fa, fb, reps=REPS):
    """Interleaved A/B timing (see bench_resident): min us/call for each
    plus the median of per-pair ratios (drift-robust on a shared box)."""
    fa(), fb(), fa(), fb()
    ta, tb = [], []
    for i in range(reps):
        pair = (fa, ta), (fb, tb)
        for fn, acc in (pair if i % 2 == 0 else pair[::-1]):
            t0 = time.perf_counter()
            fn()
            acc.append(time.perf_counter() - t0)
    ratios = sorted(b / a for a, b in zip(ta, tb))
    return (min(ta) * 1e6, min(tb) * 1e6, ratios[len(ratios) // 2])


def _no_prune(col, pages, qual):
    return pages, None


def _unpruned(fn):
    """Run ``fn`` with page pruning patched out -- the pre-PR baseline.

    ``pdo.prune_page_list`` is the binding every retrieval path resolves
    (the numpy engine routes through ``pdo.decode_row_ranges``), and the
    patched run keeps ``pruned=False`` staging, whose shapes the padding
    ladder makes identical to the pruned run's -- so A and B share one
    jit cache and the timing deltas are pruning, not retraces.
    """
    def run():
        saved = pdo.prune_page_list
        pdo.prune_page_list = _no_prune
        try:
            return fn()
        finally:
            pdo.prune_page_list = saved
    return run


def _fixture():
    # community-local graph (see bench_partition): each page's dst hull
    # tracks its source range, the regime GraphAr's chunked layouts put
    # you in.  Clipped, not wrapped: one wrap-around edge would stretch
    # a boundary page's min/max across the whole id space.
    off = np.concatenate([np.arange(-(DEG // 2), 0),
                          np.arange(1, DEG - DEG // 2 + 1)])
    src = np.repeat(np.arange(N), len(off))
    dst = np.clip(np.arange(N)[:, None] + off[None, :], 0, N - 1).ravel()
    return build_adjacency(src, dst, N, N, BY_SRC, ENC_GRAPHAR,
                           page_size=PAGE)


def _vt():
    labels = {"HOT": np.arange(N) < N // 8,
              "ALL": np.ones(N, bool)}
    return VertexTable.build(
        VertexTypeSchema("v", [PropertySchema("age", "int64")],
                         labels=["HOT", "ALL"], page_size=PAGE),
        {"age": np.arange(N, dtype=np.int64)}, labels, num_vertices=N)


def _check(adj, vs, engine, make_filt, expect_savings):
    """Bit-identity + meter ordering vs the unpruned baseline."""
    m_a, m_b = IOMeter(), IOMeter()
    want = _unpruned(lambda: retrieve_neighbors_batch(
        adj, vs, PAGE, m_a, engine=engine, fused=engine != "numpy",
        resident=engine != "numpy", filter=make_filt()))()
    got = retrieve_neighbors_batch(
        adj, vs, PAGE, m_b, engine=engine, fused=engine != "numpy",
        resident=engine != "numpy", filter=make_filt())
    assert got == want, "pruned ids must match the unpruned oracle"
    if expect_savings:
        assert m_b.nbytes < m_a.nbytes, "selective hull must save I/O"
    else:
        assert (m_b.nbytes, m_b.nrequests) == (m_a.nbytes, m_a.nrequests), \
            "all-true hull must cost exactly the unpruned path"
    return m_a.nbytes, m_b.nbytes


def _engines():
    eng = ["numpy", "jax", "pallas"]
    if INTERP:
        eng.append("pallas_interp")  # same engine, explicit interp row tag
    return eng


def _resolve(engine):
    return ("pallas", "_interp") if engine == "pallas_interp" \
        else (engine, "")


def run() -> None:
    adj = _fixture()
    vt = _vt()
    col = adj.table["<dst>"].encoded
    AGE = NumProp("age")

    sections = (
        ("label", lambda: LabelFilter(vt, L("HOT")), True),
        ("numeric", lambda: NumericFilter(vt, AGE < N // 8), True),
        ("unpruned", lambda: LabelFilter(vt, L("ALL")), False),
    )
    for engine in _engines():
        eng, tag = _resolve(engine)
        for bs in BATCH_SIZES:
            vs = np.random.default_rng(bs).integers(0, N, bs)
            for name, make_filt, saves in sections:
                nb_un, nb_pr = _check(adj, vs, eng, make_filt, saves)
                filt = make_filt()
                fused = eng != "numpy"
                fp = lambda: retrieve_neighbors_batch(
                    adj, vs, PAGE, engine=eng, fused=fused,
                    resident=fused, filter=filt)
                fu = _unpruned(fp)
                before = (col.prune_stats.pages_pruned,
                          col.prune_stats.io_saved_bytes)
                fp()
                d_pages = col.prune_stats.pages_pruned - before[0]
                d_bytes = col.prune_stats.io_saved_bytes - before[1]
                t_pr, t_un, ratio = _paired(fp, fu)
                row = "page_pruned" if saves else "page"
                emit(f"{row}_{name}_{eng}{tag}_bs{bs}", t_pr,
                     f"unpruned_us={t_un:.2f};"
                     f"unpruned_over_pruned={ratio:.2f};"
                     f"pages_pruned={d_pages};"
                     f"io_saved_pct={100 * (1 - nb_pr / max(nb_un, 1)):.0f};"
                     f"io_saved_bytes={d_bytes};ids_identical=1")
                emit(f"{row}_{name}_{eng}{tag}_bs{bs}:speedup_pct",
                     100 * ratio, "")

    # ---- steady state: pruning never mints a new jit shape ----------------
    rng = np.random.default_rng(7)
    filt = LabelFilter(vt, L("HOT"))
    tick = lambda bs: retrieve_neighbors_batch(
        adj, rng.integers(0, N, bs), PAGE, engine="jax", fused=True,
        resident=True, filter=filt)
    ticks = (16, 24, 40, 64) if SMOKE else (16, 64, 200, 512)
    stable = 0
    for _ in range(30):  # warm until the pow2 ladder is covered
        t0 = _pad.trace_count()
        for bs in ticks:
            tick(bs)
        stable = stable + 1 if _pad.trace_count() == t0 else 0
        if stable >= 3:
            break
    before = _pad.trace_count()
    for _ in range(5):
        for bs in ticks:
            tick(bs)
    retraces = _pad.trace_count() - before
    assert retraces == 0, "pruned steady state must not retrace"
    emit("page_pruned_steady_retraces", float(retraces), "target=0")
