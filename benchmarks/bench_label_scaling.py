"""Fig. 14 -- execution time vs number of OR'ed labels.

Reproduces the paper's crossover: the merge-based interval method wins
while merged-interval counts stay small, and degrades past the point where
nearly every vertex boundary becomes a breakpoint (scattered labels),
where the per-vertex binary(RLE) scan catches up."""
from __future__ import annotations

import functools

import numpy as np

from repro.core import L, VertexTypeSchema, filter_binary_columns, \
    filter_rle_interval
from repro.core.vertex import LABEL_ENC_RLE, VertexTable
from repro.data.synthetic import clustered_labels, scattered_labels

from .util import emit, timeit


def _or_chain(names):
    cond = L(names[0])
    for m in names[1:]:
        cond = cond | L(m)
    return cond


def run() -> None:
    n = 60_000
    for kind, gen in (("clustered", clustered_labels),
                      ("scattered", scattered_labels)):
        k = 32
        names = [f"L{i}" for i in range(k)]
        if kind == "clustered":
            cols = gen(n, names, density=0.15, run_scale=1024, seed=3)
        else:
            cols = gen(n, names, density=0.15, seed=3)
        schema = VertexTypeSchema("v", [], labels=names)
        vt = VertexTable.build(schema, {}, cols, LABEL_ENC_RLE,
                               num_vertices=n)
        for i in (1, 2, 4, 8, 16, 32):
            cond = _or_chain(names[:i])
            t_int = timeit(lambda: filter_rle_interval(vt, cond), repeats=3)
            t_scan = timeit(lambda: filter_binary_columns(vt, cond),
                            repeats=3)
            emit(f"fig14_scaling_{kind}_k{i}_interval", t_int,
                 f"scan_us={t_scan:.1f};interval_wins={int(t_int < t_scan)}")
