"""Device-resident column plane (PR 4): resident vs per-dispatch pack.

Three sections:

* ``resident_fused_*`` -- the fused decode->bitmap dispatch with the
  device-resident packed mirror (page indices shipped, on-device gather)
  against the PR 3 per-dispatch pack path (host row-gather + device_put
  every call), per engine and batch size;

* ``resident_filtered_*`` -- the same comparison for the fused
  predicate-pushdown path, where residency additionally replaces the
  per-dispatch label-plane shipping + per-lane program evaluation with a
  device-cached predicate bitmap plane (the acceptance row: >= 2x at
  batch 64, never slower);

* ``resident_steady_*`` -- a 100-dispatch steady-state serving run over
  varying frontier sizes with the decoded-page LRU warm: asserts **zero
  jit retraces** (pow2 size-class padding keeps every dispatch inside
  the jit cache) and reports the retrace counter in the derived column.

Every timed comparison is preceded by a bit-identity + IOMeter-identity
assertion against the numpy oracle -- residency must be invisible except
in wall time.  ``REPRO_BENCH_SMOKE=1`` shrinks the graph so CI can run
the suite in seconds.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import (BY_SRC, ENC_GRAPHAR, IOMeter, L, LabelFilter,
                        attach_page_cache, build_adjacency,
                        retrieve_neighbors_batch)
from repro.core.schema import VertexTypeSchema
from repro.core.vertex import VertexTable
from repro.kernels import _pad

from .util import emit, timeit

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
N = 2_000 if SMOKE else 20_000
DEG = 8 if SMOKE else 16
PAGE = 512 if SMOKE else 2048
BATCH_SIZES = (8,) if SMOKE else (8, 64, 512)
FILTER_BATCH_SIZES = (8,) if SMOKE else (64, 128, 512)
STEADY_DISPATCHES = 10 if SMOKE else 100


def _fixture():
    from repro.data.synthetic import clustered_labels, powerlaw_graph
    src, dst = powerlaw_graph(N, DEG, locality=0.85, seed=11)
    adj = build_adjacency(src, dst, N, N, BY_SRC, ENC_GRAPHAR,
                          page_size=PAGE)
    labels = clustered_labels(N, ["A", "B", "C"], density=0.3,
                              run_scale=max(PAGE // 8, 64), seed=7)
    vt = VertexTable.build(
        VertexTypeSchema("v", [], labels=["A", "B", "C"], page_size=PAGE),
        {}, labels, num_vertices=N)
    return adj, vt


def _paired(fa, fb, reps=32):
    """Interleaved A/B timing (microseconds) + drift-robust speedup.

    The resident-vs-per-dispatch rows are ratios measured on a shared
    machine whose load wanders on a scale of seconds-to-minutes, so the
    two variants are sampled in adjacent pairs (noise common to a pair
    cancels in its ratio) with the within-pair order alternating
    A-B / B-A (so drift across the pair's two slots cancels on average
    instead of biasing one variant), and the speedup is the **median of
    per-pair ratios** -- medians shed GC / scheduler outliers.
    Absolute us/call columns report each variant's minimum, the usual
    best-case estimator.  Returns ``(min_a_us, min_b_us, b_over_a)``.
    """
    fa(), fb(), fa(), fb()           # warm jit caches both ways
    ta, tb = [], []
    for i in range(reps):
        pair = (fa, ta), (fb, tb)
        for fn, acc in (pair if i % 2 == 0 else pair[::-1]):
            t0 = time.perf_counter()
            fn()
            acc.append(time.perf_counter() - t0)
    ratios = sorted(b / a for a, b in zip(ta, tb))
    return (min(ta) * 1e6, min(tb) * 1e6, ratios[len(ratios) // 2])


def _check_identity(adj, vs, engine, filt=None):
    """Residency must not change ids or meters (vs oracle + per-dispatch)."""
    m_res, m_leg, m_np = IOMeter(), IOMeter(), IOMeter()
    f = (lambda: LabelFilter(filt.vt, filt.cond)) if filt else lambda: None
    res = retrieve_neighbors_batch(adj, vs, PAGE, m_res, engine=engine,
                                   fused=True, resident=True, filter=filt)
    leg = retrieve_neighbors_batch(adj, vs, PAGE, m_leg, engine=engine,
                                   fused=True, resident=False, filter=filt)
    want = retrieve_neighbors_batch(adj, vs, PAGE, m_np, engine="numpy",
                                    filter=f())
    assert res == leg == want, "resident path must match the host oracle"
    assert (m_res.nbytes, m_res.nrequests) == (m_leg.nbytes, m_leg.nrequests) \
        == (m_np.nbytes, m_np.nrequests), \
        "resident path must charge exactly what the numpy engine does"


def run() -> None:
    adj, vt = _fixture()
    col = adj.table["<dst>"].encoded

    # ---- fused retrieval: resident vs per-dispatch pack -------------------
    for engine in ("jax", "pallas"):
        for bs in BATCH_SIZES:
            vs = np.random.default_rng(bs).integers(0, N, bs)
            _check_identity(adj, vs, engine)
            t_res, t_leg, speedup = _paired(
                lambda: retrieve_neighbors_batch(
                    adj, vs, PAGE, engine=engine, fused=True, resident=True),
                lambda: retrieve_neighbors_batch(
                    adj, vs, PAGE, engine=engine, fused=True,
                    resident=False))
            emit(f"resident_fused_{engine}_bs{bs}", t_res,
                 f"perdispatch_us={t_leg:.2f};"
                 f"resident_over_perdispatch={speedup:.2f};"
                 f"io_identical=1")
            emit(f"perdispatch_fused_{engine}_bs{bs}", t_leg, "")

    # ---- fused filtered retrieval (the acceptance rows) -------------------
    cond = L("A") | L("C")
    for engine in ("jax", "pallas"):
        for bs in FILTER_BATCH_SIZES:
            vs = np.random.default_rng(bs).integers(0, N, bs)
            filt = LabelFilter(vt, cond)
            _check_identity(adj, vs, engine, filt)
            t_res, t_leg, speedup = _paired(
                lambda: retrieve_neighbors_batch(
                    adj, vs, PAGE, engine=engine, fused=True, resident=True,
                    filter=filt),
                lambda: retrieve_neighbors_batch(
                    adj, vs, PAGE, engine=engine, fused=True, resident=False,
                    filter=filt))
            emit(f"resident_filtered_{engine}_bs{bs}", t_res,
                 f"perdispatch_us={t_leg:.2f};"
                 f"resident_over_perdispatch={speedup:.2f};"
                 f"io_identical=1")
            emit(f"perdispatch_filtered_{engine}_bs{bs}", t_leg, "")

    # ---- steady-state serving: zero retraces over 100 dispatches ----------
    for engine in ("jax", "pallas"):
        rng = np.random.default_rng(5)
        cache = attach_page_cache(col, 4096)
        sizes = rng.integers(33, 65, size=STEADY_DISPATCHES)
        batches = [rng.integers(0, N, s) for s in sizes]
        for vs in batches:       # warm jit size classes + the LRU
            retrieve_neighbors_batch(adj, vs, PAGE, engine=engine,
                                     fused=True, resident=True)
        before = _pad.trace_count()
        t0 = timeit(lambda: [retrieve_neighbors_batch(
            adj, vs, PAGE, engine=engine, fused=True, resident=True)
            for vs in batches], repeats=3, warmup=0)
        retraces = _pad.trace_count() - before
        assert retraces == 0, \
            f"steady-state serving retraced {retraces}x on {engine}"
        col.page_cache = None
        emit(f"resident_steady_{engine}_{STEADY_DISPATCHES}disp",
             t0 / STEADY_DISPATCHES,
             f"dispatches={STEADY_DISPATCHES};retraces=0;"
             f"lru_hits={cache.hits}")
