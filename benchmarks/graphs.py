"""Shared benchmark graphs (Table 1 stand-ins, scaled for CPU wall-time).

The paper's graphs range to billions of edges; these keep the same
*statistical shape* (power-law degrees, ID locality, clustered labels) at
CPU-friendly sizes.  Abbreviations mirror Table 1 spirit.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import numpy as np

from repro.data.synthetic import (clustered_labels, ldbc_like, powerlaw_graph,
                                  scattered_labels)

TOPOLOGY_GRAPHS = {
    # name: (num_vertices, avg_degree, locality)
    "CI": (50_000, 5, 0.85),      # citations-like
    "OL": (100_000, 8, 0.80),     # offshore-leaks-like
    "HW": (60_000, 40, 0.90),     # hollywood-like (dense)
    "WK": (150_000, 12, 0.75),    # wiki-like
}

LABEL_GRAPHS = {
    # name: (num_vertices, labels, density, run_scale)
    "BL": (40_000, 8, 0.25, 512),
    "AX": (80_000, 6, 0.30, 1024),
    "MA": (120_000, 16, 0.20, 256),
    "PO": (30_000, 4, 0.35, 2048),
}


@functools.lru_cache(maxsize=None)
def topology(name: str) -> Tuple[int, np.ndarray, np.ndarray]:
    n, deg, loc = TOPOLOGY_GRAPHS[name]
    src, dst = powerlaw_graph(n, deg, locality=loc, seed=hash(name) % 997)
    return n, src, dst


@functools.lru_cache(maxsize=None)
def labels(name: str):
    n, k, dens, run = LABEL_GRAPHS[name]
    names = [f"L{i}" for i in range(k)]
    return n, names, clustered_labels(n, names, density=dens,
                                      run_scale=run, seed=hash(name) % 991)


@functools.lru_cache(maxsize=None)
def snb(scale: int = 1):
    return ldbc_like(scale=scale, seed=0)
