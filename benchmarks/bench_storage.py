"""Fig. 8 -- topology storage: plain vs plain+offset vs GraphAr (delta)."""
from __future__ import annotations

from repro.core import BY_SRC, ENC_GRAPHAR, ENC_OFFSET, ENC_PLAIN, \
    build_adjacency

from .graphs import TOPOLOGY_GRAPHS, topology
from .util import emit


def run() -> None:
    for name in TOPOLOGY_GRAPHS:
        n, src, dst = topology(name)
        plain = build_adjacency(src, dst, n, n, BY_SRC, ENC_PLAIN)
        offset = build_adjacency(src, dst, n, n, BY_SRC, ENC_OFFSET)
        graphar = build_adjacency(src, dst, n, n, BY_SRC, ENC_GRAPHAR)
        b_p = plain.topology_nbytes()
        b_o = offset.topology_nbytes()
        b_g = graphar.topology_nbytes()
        emit(f"fig8_storage_{name}_plain_bytes", 0.0, str(b_p))
        emit(f"fig8_storage_{name}_plain_offset_bytes", 0.0, str(b_o))
        emit(f"fig8_storage_{name}_graphar_bytes", 0.0,
             f"{b_g};ratio_vs_plain_offset={b_g/b_o:.3f}")
