"""Table 2 -- storage media: tmpfs / ESSD / OSS modeled execution.

Per the paper's setting the data lake is I/O-bound: modeled seconds =
decode wall time + IOMeter bytes/requests through each medium's
bandwidth/latency (ESSD = the paper's measured 180 MB/s PL0 volume)."""
from __future__ import annotations

import numpy as np

from repro.core import (BY_SRC, ENC_GRAPHAR, ENC_PLAIN, IOMeter, L,
                        VertexTypeSchema, build_adjacency, degrees_topk,
                        filter_rle_interval, filter_string,
                        retrieve_neighbors, retrieve_neighbors_scan)
from repro.core.storage import MEDIA
from repro.core.vertex import (LABEL_ENC_RLE, LABEL_ENC_STRING, VertexTable)

from .graphs import labels, topology
from .util import emit, timeit


def run() -> None:
    n, src, dst = topology("WK")
    plain = build_adjacency(src, dst, n, n, BY_SRC, ENC_PLAIN)
    graphar = build_adjacency(src, dst, n, n, BY_SRC, ENC_GRAPHAR)
    v = int(degrees_topk(graphar)[0])

    ln, names, cols = labels("MA")
    schema = VertexTypeSchema("v", [], labels=names)
    vt_str = VertexTable.build(schema, {}, cols, LABEL_ENC_STRING,
                               num_vertices=ln)
    vt_rle = VertexTable.build(schema, {}, cols, LABEL_ENC_RLE,
                               num_vertices=ln)

    m_pl, m_gar = IOMeter(), IOMeter()
    t_pl = timeit(lambda: retrieve_neighbors_scan(plain, v, 2048, None),
                  repeats=3) / 1e6
    retrieve_neighbors_scan(plain, v, 2048, m_pl)
    t_gar = timeit(lambda: retrieve_neighbors(graphar, v, 2048, None)) / 1e6
    retrieve_neighbors(graphar, v, 2048, m_gar)

    m_str, m_int = IOMeter(), IOMeter()
    t_str = timeit(lambda: filter_string(vt_str, L(names[0])),
                   repeats=3) / 1e6
    filter_string(vt_str, L(names[0]), m_str)
    t_int = timeit(lambda: filter_rle_interval(vt_rle, L(names[0]))) / 1e6
    filter_rle_interval(vt_rle, L(names[0]), m_int)

    for mname, media in MEDIA.items():
        nr_pl = t_pl + m_pl.seconds(media)
        nr_gar = t_gar + m_gar.seconds(media)
        lf_str = t_str + m_str.seconds(media)
        lf_int = t_int + m_int.seconds(media)
        emit(f"table2_{mname}_neighbor_plain_s", nr_pl * 1e6, "")
        emit(f"table2_{mname}_neighbor_graphar_s", nr_gar * 1e6,
             f"speedup={nr_pl/nr_gar:.1f}x")
        emit(f"table2_{mname}_label_string_s", lf_str * 1e6, "")
        emit(f"table2_{mname}_label_graphar_s", lf_int * 1e6,
             f"speedup={lf_str/lf_int:.1f}x")
