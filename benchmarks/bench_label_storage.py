"""Fig. 11 -- label storage: string vs binary(plain) vs binary(RLE)."""
from __future__ import annotations

from repro.core import VertexTypeSchema
from repro.core.vertex import (LABEL_ENC_PLAIN, LABEL_ENC_RLE,
                               LABEL_ENC_STRING, VertexTable)

from .graphs import LABEL_GRAPHS, labels
from .util import emit


def run() -> None:
    for name in LABEL_GRAPHS:
        n, names, cols = labels(name)
        schema = VertexTypeSchema("v", [], labels=names)
        sizes = {}
        for enc in (LABEL_ENC_STRING, LABEL_ENC_PLAIN, LABEL_ENC_RLE):
            vt = VertexTable.build(schema, {}, cols, enc, num_vertices=n)
            sizes[enc] = vt.labels_nbytes()
        emit(f"fig11_labels_{name}_string_bytes", 0.0, str(sizes["string"]))
        emit(f"fig11_labels_{name}_binary_plain_bytes", 0.0,
             str(sizes["plain"]))
        emit(f"fig11_labels_{name}_binary_rle_bytes", 0.0,
             f"{sizes['rle']};vs_string={sizes['rle']/sizes['string']:.4f};"
             f"vs_plain={sizes['rle']/sizes['plain']:.4f}")
