"""Kernel decode-path microbenchmarks.

NOTE: Pallas runs here in interpret mode (CPU container) -- wall times
characterize the *harness*, not TPU performance; TPU perf is covered by
the roofline analysis.  The numpy-codec numbers are the storage-plane
baseline the kernels are validated against."""
from __future__ import annotations

import numpy as np

from repro.core.encoding import delta_encode_column, rle_encode_bool
from repro.core.pac import PAC
from repro.kernels.bitmap_select import ops as bso
from repro.kernels.pac_decode import ops as pdo
from repro.kernels.rle_filter import ops as rfo

from .util import emit, timeit


def run() -> None:
    rng = np.random.default_rng(0)
    ids = np.sort(rng.integers(0, 1 << 22, size=200_000))
    col = delta_encode_column(ids, 2048)
    n_pages = len(col.pages)

    t_np = timeit(lambda: pdo.decode_pages(col, 0, n_pages,
                                           use_pallas=False), repeats=3)
    t_pl = timeit(lambda: pdo.decode_pages(col, 0, n_pages,
                                           use_pallas=True), repeats=3)
    emit("kern_delta_decode_jnp_ref", t_np, f"pages={n_pages}")
    emit("kern_delta_decode_pallas_interp", t_pl, "interpret=1")

    dense = rng.random(500_000) < 0.2
    rle = rle_encode_bool(dense)
    t_np = timeit(lambda: rfo.rle_to_bitmap(rle, True, use_pallas=False),
                  repeats=3)
    t_pl = timeit(lambda: rfo.rle_to_bitmap(rle, True, use_pallas=True),
                  repeats=3)
    emit("kern_rle_filter_jnp_ref", t_np, f"runs={rle.n_runs}")
    emit("kern_rle_filter_pallas_interp", t_pl, "interpret=1")

    vals = rng.standard_normal(200_000).astype(np.float32)
    sel = np.unique(rng.integers(0, len(vals), 5_000))
    pac = PAC.from_ids(sel, 2048)
    pages = {p: vals[p * 2048:(p + 1) * 2048] for p in pac.pages()}
    t_np = timeit(lambda: bso.select_from_pages(pac, pages,
                                                use_pallas=False), repeats=3)
    t_pl = timeit(lambda: bso.select_from_pages(pac, pages,
                                                use_pallas=True), repeats=3)
    emit("kern_bitmap_select_jnp_ref", t_np, f"sel={len(sel)}")
    emit("kern_bitmap_select_pallas_interp", t_pl, "interpret=1")
