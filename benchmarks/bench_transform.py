"""Fig. 10 -- data transformation breakdown: sort / offset / output."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (EdgeTypeSchema, GraphArBuilder, PropertySchema,
                        VertexTypeSchema)

from .graphs import topology
from .util import emit


def run() -> None:
    for name in ("WK", "HW"):
        n, src, dst = topology(name)
        b = GraphArBuilder(name)
        b.add_vertices(VertexTypeSchema("v", []), {}, num_vertices=n)
        t0 = time.perf_counter()
        b.add_edges(EdgeTypeSchema("v", "e", "v",
                                   adjacency=["by_src", "by_dst"]),
                    src, dst)
        total = time.perf_counter() - t0
        t = b.timing
        eps = len(src) * 2 / max(total, 1e-9)  # two layouts
        emit(f"fig10_transform_{name}_sort", t.sort * 1e6, "")
        emit(f"fig10_transform_{name}_offset", t.offset * 1e6, "")
        emit(f"fig10_transform_{name}_output", t.output * 1e6, "")
        emit(f"fig10_transform_{name}_edges_per_s", 0.0, f"{eps:.0f}")
