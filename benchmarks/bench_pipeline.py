"""Framework benchmark -- GraphAr lake -> trainer ingestion throughput."""
from __future__ import annotations

import time

from repro.core import (EdgeTypeSchema, GraphArBuilder, L, PropertySchema,
                        VertexTypeSchema)
from repro.data.pipeline import GraphCorpusPipeline, PipelineConfig
from repro.data.synthetic import document_graph

from .util import emit


def run() -> None:
    lake = document_graph(num_docs=4000, vocab=4096, mean_len=256, seed=1)
    b = GraphArBuilder("docs")
    b.add_vertices(
        VertexTypeSchema("doc", [PropertySchema("tokens", "tokens")],
                         labels=list(lake.labels), page_size=1024),
        {"tokens": lake.tokens}, lake.labels)
    b.add_edges(EdgeTypeSchema("doc", "links", "doc", page_size=1024),
                lake.links_src, lake.links_dst)
    g = b.build()
    cond = L("HighQuality") | L("News")
    cfg = PipelineConfig(seq_len=1024, batch_size=8)
    pipe = GraphCorpusPipeline(g, cond, cfg)
    it = pipe.batches()
    next(it)  # warm
    t0 = time.perf_counter()
    steps = 20
    for _ in range(steps):
        next(it)
    dt = time.perf_counter() - t0
    toks = steps * cfg.seq_len * cfg.batch_size
    emit("pipeline_tokens_per_s", dt / steps * 1e6, f"{toks/dt:.0f}")
    emit("pipeline_io_bytes", 0.0, str(pipe.io_stats().nbytes))
