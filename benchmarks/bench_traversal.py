"""Fused multi-hop traversal (PR 6): one-dispatch k-hop vs host loop.

Two sections:

* ``traversal_khop_*`` -- the fused k-hop (all hops one scan-stepped
  dispatch over the device-resident frontier plane, no host-side id
  materialization between hops) against the host-loop oracle on the
  same engine (per hop: offsets gather, device decode, host
  visited-mask bookkeeping), per engine and hop count.  The acceptance
  rows: >= 2x at hops >= 2 on the kernel engines.

* ``traversal_steady_*`` -- a 100-traversal steady-state run over
  varying seed batches: asserts **zero jit retraces** (seed vectors pad
  to pow2 size classes; the hop count is a static scan length) and
  reports the single-round-trip dispatch counters.

Every timed comparison is preceded by a bit-identity + IOMeter-identity
assertion against the numpy oracle -- fusion must be invisible except
in wall time.  ``REPRO_BENCH_SMOKE=1`` shrinks the graph so CI can run
the suite in seconds.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import (BY_SRC, ENC_GRAPHAR, IOMeter, build_adjacency,
                        k_hop)
from repro.kernels import _pad
from repro.kernels.traversal import ops as trav

from .util import emit, timeit

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
N = 2_000 if SMOKE else 20_000
DEG = 8 if SMOKE else 16
PAGE = 512 if SMOKE else 2048
SEEDS = 8 if SMOKE else 64       # a serving tick's worth of seeds
HOP_COUNTS = (1, 2) if SMOKE else (1, 2, 3)
STEADY_TRAVERSALS = 10 if SMOKE else 100


def _fixture():
    from repro.data.synthetic import powerlaw_graph
    src, dst = powerlaw_graph(N, DEG, locality=0.85, seed=11)
    return build_adjacency(src, dst, N, N, BY_SRC, ENC_GRAPHAR,
                           page_size=PAGE)


def _paired(fa, fb, reps=32):
    """Interleaved A/B timing (microseconds) + drift-robust speedup
    (median of per-pair ratios, within-pair order alternating; see
    bench_resident._paired for the full rationale)."""
    fa(), fb(), fa(), fb()           # warm jit caches both ways
    ta, tb = [], []
    for i in range(reps):
        pair = (fa, ta), (fb, tb)
        for fn, acc in (pair if i % 2 == 0 else pair[::-1]):
            t0 = time.perf_counter()
            fn()
            acc.append(time.perf_counter() - t0)
    ratios = sorted(b / a for a, b in zip(ta, tb))
    return (min(ta) * 1e6, min(tb) * 1e6, ratios[len(ratios) // 2])


def _check_identity(adj, seeds, hops, engine):
    """Fusion must not change ids or meters (vs host loop + oracle)."""
    m_fus, m_loop, m_np = IOMeter(), IOMeter(), IOMeter()
    fus = k_hop(adj, seeds, hops, m_fus, engine=engine)
    loop = k_hop(adj, seeds, hops, m_loop, engine=engine, fused=False)
    want = k_hop(adj, seeds, hops, m_np)
    assert np.array_equal(fus, want) and np.array_equal(loop, want), \
        "fused k-hop must match the host oracle"
    assert (m_fus.nbytes, m_fus.nrequests) == (m_np.nbytes, m_np.nrequests), \
        "fused k-hop must charge exactly what the numpy oracle does"


def run() -> None:
    adj = _fixture()

    # ---- fused k-hop vs per-hop host loop (the acceptance rows) -----------
    for engine in ("jax", "pallas"):
        for hops in HOP_COUNTS:
            seeds = np.random.default_rng(hops).integers(0, N, SEEDS)
            _check_identity(adj, seeds, hops, engine)
            t_fus, t_loop, speedup = _paired(
                lambda: k_hop(adj, seeds, hops, engine=engine),
                lambda: k_hop(adj, seeds, hops, engine=engine,
                              fused=False))
            emit(f"traversal_khop_{engine}_h{hops}", t_fus,
                 f"hostloop_us={t_loop:.2f};"
                 f"fused_over_hostloop={speedup:.2f};io_identical=1")
            emit(f"hostloop_khop_{engine}_h{hops}", t_loop, "")

    # ---- steady-state serving: zero retraces over 100 traversals ----------
    for engine in ("jax", "pallas"):
        rng = np.random.default_rng(5)
        sizes = rng.integers(2, 33, size=STEADY_TRAVERSALS)
        batches = [rng.integers(0, N, s) for s in sizes]
        for vs in batches:           # warm the jit size classes
            k_hop(adj, vs, 2, engine=engine)
        plan = trav.traversal_plan(adj, engine)
        d0, r0 = plan.dispatches, plan.device_roundtrips
        before = _pad.trace_count()
        t0 = timeit(lambda: [k_hop(adj, vs, 2, engine=engine)
                             for vs in batches], repeats=3, warmup=0)
        retraces = _pad.trace_count() - before
        assert retraces == 0, \
            f"steady-state traversal retraced {retraces}x on {engine}"
        emit(f"traversal_steady_{engine}_{STEADY_TRAVERSALS}trav",
             t0 / STEADY_TRAVERSALS,
             f"traversals={STEADY_TRAVERSALS};retraces=0;"
             f"roundtrips_per_traversal="
             f"{(plan.device_roundtrips - r0) // max(plan.dispatches - d0, 1)}")
